"""DSE-speed suite: measures what the incremental engine buys, per workload.

``auto_dse`` is the pipeline entry point for search (its two stages run
as ``pipeline.PassManager`` passes with counter-neutral per-stage
verifiers), so this suite measures the full pipeline-routed engine; the
evaluation counts below are unchanged from the pre-pipeline engine by
construction.

For each workload the suite runs ``auto_dse`` twice on fresh builds:

  * **baseline** — every cache disabled (``repro.core.caching.disabled()``
    + ``HlsModel(cache=False)``), i.e. the pre-incremental engine;
  * **incremental** — caches enabled, started cold
    (``caching.clear_all()``), so no state leaks between workloads.

and reports wall-seconds plus two evaluation counters:

  * ``full_node_evals`` — per-node cost computations that performed a fresh
    recurrence-II/dependence analysis (every node computation in the
    baseline engine is one of these);
  * ``analysis_evals`` — all fresh full-cost analyses run by the engine:
    self-dependence derivations, legality checks, trip-count (FM bound)
    derivations, and the recurrence-II computations above.  This is the
    suite's headline "cost-model evaluation count": it counts exactly the
    polyhedral work the pre-PR engine redid from scratch per candidate.

Counters, unlike wall time, are stable on shared hardware; both engines
must produce identical action logs and DesignReports (checked here and in
``tests/test_incremental_dse.py``).

Bound-and-confirm columns: ``incremental_confirmed_evals`` /
``incremental_pruned_candidates`` count the rung candidates that reached
a full ``node_report`` confirmation vs those the admissible closed-form
latency lower bound pruned (``POM_BOUND_PRUNE``); each strategy row's
telemetry carries the same pair.  The ``--check`` gate fails on a >10%
confirmed-eval regression alongside the analysis-eval gate.

The ``conv_stack`` workload mirrors ``bench_apps.run_dnn``'s per-layer
pattern (unoptimized report + full-budget DSE + split-budget DSE over a
ResNet-style stack with repeated layer shapes) — the exact load that made
the ``image`` suite too slow for fast mode before this engine existed.
``conv_chain`` is the same stack as ONE multi-statement function, the
task-level-pipelining workload.

Dataflow columns: per workload, the DSE'd designs are re-aggregated under
``dataflow=False`` (sequential sum of fusion groups) and ``dataflow=True``
(streaming task graph), recording summed latency and BRAM18 per mode plus
the number of applied regions — the latency/BRAM price of task overlap.

Search-strategy columns (PR 3, widened for the parallel beam): each
workload is additionally searched with ``greedy``, ``beam:2``,
``beam:4``, the pooled ``beam:8:parallel``, and ``parallel:2``,
recording wall-seconds *and* best design cost (summed report latency),
so the snapshot tracks search **quality** alongside search speed.  Each
strategy wall is the best (min) of ``STRATEGY_REPEATS`` cold-cache
repeats, interleaved round-robin across strategies so machine drift
lands evenly — and the ``beam_scaling`` ratio (``beam8`` wall /
``greedy`` wall) is the headline number for the cross-state wave:
instead of the naive ~8x it sits near 1x where sibling states collapse
onto shared rungs (gemm) and ~2-3x where the eight states genuinely
diverge (3mm evaluates ~6x the rungs of ``beam:1``; the
transformed-node/whole-design caches absorb the rest).  The snapshot
records the host ``cpus``: on a multi-core box the pooled wave
dispatches states concurrently on top of that; on one core it degrades
to the bit-identical serial wave.  The ``fusion_prepass`` section runs graph-level fusion
(``graph_passes=("fuse",)``) ahead of DSE on the multi-statement
workloads and records the final cost against the default flow, where
stage 1 distributes conflicting fusion groups and conservatively
re-fuses (the paper's split-interchange-merge).

Emits ``BENCH_dse_speed.json`` next to the repo root for snapshot diffing.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List, Tuple

from repro.core import caching
from repro.core.cost_model import XC7Z020, HlsModel
from repro.core.designdb import atomic_write_json
from repro.core.dse import auto_dse

from .workloads import bicg, conv_chain, conv_nest, gemm, mm2, mm3

# ResNet18-style critical-layer sub-stack (out_ch, in_ch, H=W) with the
# repetition pattern real nets have; sized to keep the suite fast.
CONV_STACK: List[Tuple[int, int, int]] = (
    [(64, 3, 32)] + [(64, 64, 16)] * 4 + [(128, 64, 8)] + [(128, 128, 8)] * 3
)


def _conv_builders() -> List[Callable]:
    return [
        (lambda oc=oc, ic=ic, hw=hw, i=i:
         conv_nest(f"conv{i}", oc, ic, hw, hw).fn)
        for i, (oc, ic, hw) in enumerate(CONV_STACK)
    ]


def _run_workload(builders: List[Callable], max_parallel: int,
                  dnn_style: bool) -> Dict:
    """One engine pass over a workload's functions; returns measurements."""
    half = {k: v / 2 for k, v in XC7Z020.items()}
    t0 = time.perf_counter()
    full_evals = 0
    analytic_evals = 0
    confirmed = 0
    pruned = 0
    actions: List[List[str]] = []
    latencies: List[int] = []
    for build in builders:
        runs = [(XC7Z020, True)]
        if dnn_style:
            runs = [(XC7Z020, False), (XC7Z020, True), (half, True)]
        for resources, do_dse in runs:
            fn = build()
            model = HlsModel(resources, cache=caching.ENABLED)
            if do_dse:
                res = auto_dse(fn, max_parallel=max_parallel,
                               resources=resources, model=model)
                actions.append(list(res.actions))
                latencies.append(res.report.latency)
            else:
                latencies.append(model.design_report(fn).latency)
            full_evals += model.stats.full_node_evals
            analytic_evals += model.stats.analytic_node_evals
            confirmed += model.stats.confirmed_evals
            pruned += model.stats.pruned_candidates
    seconds = time.perf_counter() - t0
    c = caching.COUNTS
    analysis = (c["selfdep_evals"] + c["legal_evals"] + c["trip_evals"]
                + full_evals)
    transfers = (c["selfdep_transfers"] + c["legal_transfers"]
                 + c["trip_transfers"] + analytic_evals)
    return {"seconds": seconds, "full_node_evals": full_evals,
            "analysis_evals": analysis, "transfers": transfers,
            "confirmed_evals": confirmed, "pruned_candidates": pruned,
            "actions": actions, "latencies": latencies}


# search strategies measured per workload: label -> auto_dse kwargs.
# ``beam8`` runs the *pooled* wave beam (``beam:8:parallel``) — on a box
# where the pool cannot win (single core, or fork unavailable) the
# evaluator falls back to the serial wave, which is bit-identical by
# construction, so the column is always the pooled spec's honest wall.
STRATEGY_SPECS: List[Tuple[str, Dict]] = [
    ("greedy", {}),
    ("beam2", {"strategy": "beam", "beam_width": 2}),
    ("beam4", {"strategy": "beam:4"}),
    ("beam8", {"strategy": "beam:8:parallel"}),
    ("parallel2", {"strategy": "parallel", "workers": 2}),
]

STRATEGY_REPEATS = 3


def _measure_strategies(builders: List[Callable],
                        max_parallel: int) -> Dict[str, Dict]:
    """Full-budget DSE per strategy per function, repeated
    ``STRATEGY_REPEATS`` times with cold caches each (``clear_all`` per
    repeat), reporting the **minimum** wall across repeats — the min is
    the standard noise filter on shared hardware — plus best design cost
    (identical across repeats by the determinism invariants).

    Each strategy row also carries a ``telemetry`` column summed from the
    per-run ``report.telemetry`` snapshots (see ``dse.auto_dse``): fresh
    analysis evals, cross-state dedup credits, and pool retry count.
    Counters are deterministic across cold-cache repeats, so the last
    repeat's sums stand for all of them."""
    out: Dict[str, Dict] = {}
    walls: Dict[str, List[float]] = {label: [] for label, _ in STRATEGY_SPECS}
    # repeats are interleaved round-robin across strategies (repeat 1 of
    # every strategy, then repeat 2, ...) so slow machine drift within the
    # measurement window lands evenly on every column instead of
    # penalizing whichever strategy happens to run last
    for rep in range(STRATEGY_REPEATS):
        for label, kw in STRATEGY_SPECS:
            caching.clear_all()
            caching.reset_counts()
            cost = 0
            resources: Dict[str, float] = {}
            tel = {"analysis_evals": 0, "dedup_credits": 0,
                   "pool_retries": 0, "confirmed_evals": 0,
                   "pruned_candidates": 0}
            t0 = time.perf_counter()
            for build in builders:
                res = auto_dse(build(), max_parallel=max_parallel, **kw)
                cost += res.report.latency
                for k, v in res.report.resource_totals().items():
                    resources[k] = resources.get(k, 0) + v
                t = res.report.telemetry or {}
                tel["analysis_evals"] += t.get("analysis_evals", 0)
                tel["dedup_credits"] += (t.get("wave") or {}).get(
                    "cands_credited", 0)
                tel["pool_retries"] += (t.get("pool") or {}).get(
                    "retries", 0)
                tel["confirmed_evals"] += (t.get("cost") or {}).get(
                    "confirmed_evals", 0)
                tel["pruned_candidates"] += (t.get("cost") or {}).get(
                    "pruned_candidates", 0)
            walls[label].append(time.perf_counter() - t0)
            out[label] = {"seconds": 0.0,
                          "repeats": STRATEGY_REPEATS,
                          "best_cost": cost, "resources": resources,
                          "telemetry": tel}
    for label, _ in STRATEGY_SPECS:
        out[label]["seconds"] = round(min(walls[label]), 3)
    out["beam_cost_le_greedy"] = (
        out["beam2"]["best_cost"] <= out["greedy"]["best_cost"]
        and out["beam4"]["best_cost"] <= out["greedy"]["best_cost"]
        and out["beam8"]["best_cost"] <= out["greedy"]["best_cost"])
    out["parallel_identical_to_greedy"] = (
        out["parallel2"]["best_cost"] == out["greedy"]["best_cost"])
    # wall-clock price of widening the beam 8x over the greedy trajectory
    # (cross-state dedup + the transformed-node/whole-design caches are
    # what keep this near 1 instead of near 8)
    out["beam_scaling"] = round(
        out["beam8"]["seconds"] / max(out["greedy"]["seconds"], 1e-9), 2)
    return out


def _measure_dataflow(builders: List[Callable],
                      max_parallel: int) -> Dict[str, float]:
    """Task-level-pipelining columns: per workload, the summed latency and
    BRAM18 of the DSE'd designs under the sequential aggregation
    (``dataflow=False``) and the streaming task-graph aggregation
    (``dataflow=True``), plus how many functions actually formed an
    applied dataflow region.  Single-task functions report equal numbers
    by construction."""
    caching.clear_all()
    caching.reset_counts()
    out = {"latency_off": 0, "latency_on": 0,
           "bram18_off": 0, "bram18_on": 0, "regions_applied": 0}
    for build in builders:
        fn = build()
        model = HlsModel()
        auto_dse(fn, max_parallel=max_parallel, model=model)
        fn.dataflow = False
        off = model.design_report(fn)
        fn.dataflow = True
        on = model.design_report(fn)
        out["latency_off"] += off.latency
        out["latency_on"] += on.latency
        out["bram18_off"] += off.bram18
        out["bram18_on"] += on.bram18
        if on.dataflow is not None and on.dataflow.applied:
            out["regions_applied"] += 1
    out["latency_speedup"] = round(
        out["latency_off"] / max(out["latency_on"], 1), 2)
    return out


def measure(name: str, builders: List[Callable], max_parallel: int = 256,
            dnn_style: bool = False) -> Dict:
    caching.clear_all()
    caching.reset_counts()
    with caching.disabled():
        base = _run_workload(builders, max_parallel, dnn_style)
    caching.clear_all()
    caching.reset_counts()
    inc = _run_workload(builders, max_parallel, dnn_style)
    identical = (base["actions"] == inc["actions"]
                 and base["latencies"] == inc["latencies"])
    return {
        "workload": name,
        "baseline_seconds": round(base["seconds"], 3),
        "incremental_seconds": round(inc["seconds"], 3),
        "wall_speedup": round(base["seconds"] / max(inc["seconds"], 1e-9), 2),
        "baseline_full_node_evals": base["full_node_evals"],
        "incremental_full_node_evals": inc["full_node_evals"],
        "baseline_analysis_evals": base["analysis_evals"],
        "incremental_analysis_evals": inc["analysis_evals"],
        "analysis_eval_reduction": round(
            base["analysis_evals"] / max(inc["analysis_evals"], 1), 2),
        "incremental_transfers": inc["transfers"],
        "incremental_confirmed_evals": inc["confirmed_evals"],
        "incremental_pruned_candidates": inc["pruned_candidates"],
        "identical_results": identical,
        "strategies": _measure_strategies(builders, max_parallel),
        "dataflow": _measure_dataflow(builders, max_parallel),
    }


def measure_fusion_prepass(name: str, build: Callable,
                           max_parallel: int = 64) -> Dict:
    """Graph-level fusion ahead of DSE vs the default flow (stage 1's
    distribute-then-refuse), same workload, cold caches each."""
    caching.clear_all()
    t0 = time.perf_counter()
    plain = auto_dse(build(), max_parallel=max_parallel)
    t_plain = time.perf_counter() - t0
    caching.clear_all()
    t0 = time.perf_counter()
    fused = auto_dse(build(), max_parallel=max_parallel,
                     graph_passes=("fuse",))
    t_fused = time.perf_counter() - t0
    return {
        "workload": name,
        "stage1_flow_latency": plain.report.latency,
        "prefuse_flow_latency": fused.report.latency,
        "stage1_flow_seconds": round(t_plain, 3),
        "prefuse_flow_seconds": round(t_fused, 3),
        "prefuse_stage1_actions": fused.stage1_log.actions[:4],
        "cost_no_worse": fused.report.latency <= plain.report.latency,
    }


def _suites() -> List[Tuple]:
    return [
        ("gemm", [lambda: gemm(512).fn], 256, False),
        ("bicg", [lambda: bicg(512).fn], 256, False),
        ("3mm", [lambda: mm3(256).fn], 256, False),
        ("conv_stack", _conv_builders(), 64, True),
        # the multi-statement conv stack in ONE function: the task-level
        # pipelining (dataflow) workload — conv/relu chains + rescale
        ("conv_chain", [lambda: conv_chain(20, (3, 8, 8)).fn], 16, False),
    ]


def run_all() -> List[Dict]:
    return [measure(name, builders, mp, dnn)
            for name, builders, mp, dnn in _suites()]


# --------------------------------------------------------------------------
# counter-only mode: the CI perf gate
# --------------------------------------------------------------------------
def counters_only() -> List[Dict]:
    """One incremental engine pass per workload, counters only (no
    uncached baselines, no per-strategy wall-time runs): analysis-eval
    counts are machine-independent, so this is the cheap regression gate
    CI compares against the committed snapshot."""
    out = []
    for name, builders, mp, dnn in _suites():
        caching.clear_all()
        caching.reset_counts()
        inc = _run_workload(builders, mp, dnn)
        out.append({"workload": name,
                    "incremental_analysis_evals": inc["analysis_evals"],
                    "incremental_full_node_evals": inc["full_node_evals"],
                    "incremental_transfers": inc["transfers"],
                    "incremental_confirmed_evals": inc["confirmed_evals"],
                    "incremental_pruned_candidates":
                        inc["pruned_candidates"]})
    return out


def check_against_snapshot(path: str, tolerance: float = 0.10) -> int:
    """Fail (non-zero) if any workload's ``incremental_analysis_evals`` or
    ``incremental_confirmed_evals`` regresses more than ``tolerance`` above
    the committed snapshot.  Snapshots written before bound-and-confirm
    pruning existed lack the confirmed-eval column; those skip that gate
    (regenerating the snapshot arms it)."""
    with open(path) as fh:
        snap = {r["workload"]: r for r in json.load(fh)["results"]}
    failures = 0
    for row in counters_only():
        name = row["workload"]
        ref = snap.get(name)
        if ref is None:
            print(f"{name}: not in snapshot, measured "
                  f"{row['incremental_analysis_evals']} (new workload?)")
            continue
        for col, short in (("incremental_analysis_evals", "analysis_evals"),
                           ("incremental_confirmed_evals",
                            "confirmed_evals")):
            committed = ref.get(col)
            if committed is None:
                print(f"{name}: {short} not in snapshot (pre-pruning "
                      f"snapshot?), measured {row[col]}")
                continue
            measured = row[col]
            limit = int(committed * (1 + tolerance))
            status = "OK" if measured <= limit else "REGRESSED"
            if measured > limit:
                failures += 1
            print(f"{name}: {short} {measured} vs committed {committed} "
                  f"(limit {limit}) {status}")
    return failures


def beam_microbench(repeats: int = 3) -> Dict:
    """Multi-core beam validation: wall-clock of the pooled wave beam
    (``beam:4:parallel:2``) against the serial greedy ladder on the two
    divergent-state workloads, on whatever host runs it.

    Emits ``BENCH_beam_multicore.json`` (atomic) with the per-workload
    ``beam_scaling`` ratio and the host ``cpus`` — the CI artifact the
    ROADMAP's "multi-core validation" item asks for.  On a single-core
    host the pooled wave degrades to the bit-identical serial wave, so
    the ratio there measures algorithmic dedup only; with >= 2 cores the
    wave dispatch should pull divergent-state workloads (3mm, conv_chain)
    toward 1x."""
    cases = [("gemm", [lambda: gemm(64).fn], 256),
             ("3mm", [lambda: mm3(64).fn], 256),
             ("conv_chain", [lambda: conv_chain(20, (3, 8, 8)).fn], 16)]
    rows = []
    for name, builders, mp in cases:
        walls = {"greedy": [], "beam4_parallel2": []}
        for _ in range(repeats):
            for label, kw in (("greedy", {}),
                              ("beam4_parallel2",
                               {"strategy": "beam:4:parallel:2"})):
                caching.clear_all()
                caching.reset_counts()
                t0 = time.perf_counter()
                for build in builders:
                    auto_dse(build(), max_parallel=mp, **kw)
                walls[label].append(time.perf_counter() - t0)
        g = min(walls["greedy"])
        b = min(walls["beam4_parallel2"])
        rows.append({"workload": name, "greedy_seconds": round(g, 3),
                     "beam4_parallel2_seconds": round(b, 3),
                     "beam_scaling": round(b / max(g, 1e-9), 2)})
    snap = {"suite": "beam_multicore", "cpus": os.cpu_count(),
            "results": rows}
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_beam_multicore.json")
    atomic_write_json(path, snap)
    return snap


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help="counter-only run, compared against the committed "
                         "BENCH_dse_speed.json; exits non-zero on a >10%% "
                         "analysis-eval or confirmed-eval regression")
    ap.add_argument("--microbench", action="store_true",
                    help="multi-core beam wall-clock microbench "
                         "(beam:4:parallel:2 vs greedy); writes "
                         "BENCH_beam_multicore.json with beam_scaling + "
                         "host cpus")
    ap.add_argument("--snapshot", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_dse_speed.json"))
    ap.add_argument("--tolerance", type=float, default=0.10)
    args = ap.parse_args()
    if args.check:
        failures = check_against_snapshot(args.snapshot, args.tolerance)
        raise SystemExit(1 if failures else 0)
    if args.microbench:
        snap = beam_microbench()
        print(json.dumps(snap, indent=2))
        return
    for line in csv_rows():
        print(line)


def run_fusion_compare() -> List[Dict]:
    cases = [("2mm", lambda: mm2(128).fn), ("3mm", lambda: mm3(128).fn)]
    return [measure_fusion_prepass(name, build) for name, build in cases]


def csv_rows() -> List[str]:
    rows = run_all()
    fusion = run_fusion_compare()
    # the host's core count contextualizes the beam_scaling columns: on a
    # single-core box the pooled beam degrades to the (bit-identical)
    # serial wave, so the ratio there measures pure algorithmic dedup,
    # not parallel dispatch
    snap = {"suite": "dse_speed", "cpus": os.cpu_count(),
            "results": rows, "fusion_prepass": fusion}
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_dse_speed.json")
    # atomic: an interrupted run must not corrupt the committed snapshot
    # that the --check CI gate diffs against
    atomic_write_json(path, snap)
    out = []
    for r in rows:
        strat = r["strategies"]
        df = r["dataflow"]
        out.append(
            f"dse_speed/{r['workload']},{r['incremental_seconds'] * 1e6:.0f},"
            f"wall_speedup={r['wall_speedup']}x;"
            f"analysis_evals={r['baseline_analysis_evals']}->"
            f"{r['incremental_analysis_evals']}"
            f"({r['analysis_eval_reduction']}x);"
            f"full_node_evals={r['baseline_full_node_evals']}->"
            f"{r['incremental_full_node_evals']};"
            f"confirmed_evals={r['incremental_confirmed_evals']}"
            f"(+{r['incremental_pruned_candidates']} pruned);"
            f"identical={r['identical_results']};"
            f"greedy_cost={strat['greedy']['best_cost']};"
            f"beam2_cost={strat['beam2']['best_cost']};"
            f"beam4_cost={strat['beam4']['best_cost']};"
            f"beam8_cost={strat['beam8']['best_cost']};"
            f"beam8_wall={strat['beam8']['seconds']};"
            f"beam_scaling={strat['beam_scaling']}x;"
            f"beam_le_greedy={strat['beam_cost_le_greedy']};"
            f"parallel2_identical={strat['parallel_identical_to_greedy']};"
            f"dataflow_lat={df['latency_off']}->{df['latency_on']}"
            f"({df['latency_speedup']}x);"
            f"dataflow_bram18={df['bram18_off']}->{df['bram18_on']};"
            f"dataflow_regions={df['regions_applied']}")
    for r in fusion:
        out.append(
            f"dse_speed/fuse_prepass_{r['workload']},"
            f"{r['prefuse_flow_seconds'] * 1e6:.0f},"
            f"stage1_lat={r['stage1_flow_latency']};"
            f"prefuse_lat={r['prefuse_flow_latency']};"
            f"no_worse={r['cost_no_worse']}")
    return out


if __name__ == "__main__":
    main()
