"""Fig. 14: impact analysis of individual scheduling primitives.

Applies one primitive (family) at a time to representative benchmarks and
reports speedup over the unoptimized baseline: LP (pipeline), LP+LU
(pipeline+unroll+partition), LI (interchange first), LSK (skew first), and
the full combination -- mirroring the paper's observation that different
benchmarks need different primitives (Seidel needs skewing; 2MM needs the
combination).
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.cost_model import HlsModel
from repro.core.dse import (_apply_parallel, _is_tight, refresh_partitions,
                            stage1, stage2)
from .baselines import _fn, unoptimized
from .workloads import POLYBENCH, STENCILS


def _lat(fn):
    refresh_partitions(fn)
    return HlsModel().design_report(fn).latency


def ablate(builder, size) -> Dict[str, float]:
    base = unoptimized(builder(size)).report.latency
    out = {}

    # LP: pipeline innermost only
    fn = _fn(builder(size))
    for s in fn.statements:
        s.pipeline_at = s.dims[-1]
        s.pipeline_ii = 1
    out["LP"] = base / _lat(fn)

    # LP+LU+AP: pipeline + unroll 16 + partition (no loop transforms)
    fn = _fn(builder(size))
    for s in fn.statements:
        _apply_parallel(s, (16,))
    out["LP+LU+AP"] = base / _lat(fn)

    # LI then hardware opts: stage-1 interchange/distribution only
    fn = _fn(builder(size))
    stage1(fn)
    for s in fn.statements:
        _apply_parallel(s, (16,))
    out["LI(+st1)+LU"] = base / _lat(fn)

    # full DSE
    fn = _fn(builder(size))
    stage1(fn)
    stage2(fn, HlsModel())
    out["full"] = base / _lat(fn)
    return out


BENCHES = {"bicg": (POLYBENCH["bicg"], 1024),
           "2mm": (POLYBENCH["2mm"], 512),
           "seidel": (STENCILS["seidel"], 500),
           "gemm": (POLYBENCH["gemm"], 1024)}


def run() -> List[Dict]:
    rows = []
    for name, (builder, size) in BENCHES.items():
        r = ablate(builder, size)
        r["bench"] = name
        rows.append(r)
    return rows


def csv_rows() -> List[str]:
    out = []
    for r in run():
        parts = ";".join(f"{k}={v:.1f}x" for k, v in r.items() if k != "bench")
        out.append(f"ablation/{r['bench']},0,{parts}")
    return out
