"""Table V/VI + Fig 13: image-processing pipelines and DNN conv stacks.

Image apps: POM vs ScaleHLS-like on EdgeDetect / Gaussian / Blur.
DNN apps: the paper's strategy comparison — POM runs layers sequentially
with full-board resources per layer (resource reuse), ScaleHLS-like splits
the board across layers for a dataflow pipeline whose latency is the
bottleneck layer on 1/#layers resources.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.cost_model import XC7Z020, HlsModel
from .baselines import pom, scalehls_like, unoptimized
from .workloads import IMAGE, conv_nest, conv_table

PAPER_IMAGE = {"edge_detect": (19.1, 344.0), "gaussian": (111.4, 312.0),
               "blur": (59.3, 356.0)}   # (scalehls, pom)
PAPER_DNN = {"vgg16": (33.6, 86.8), "resnet18": (50.8, 46.4)}


def run_image(size: int = 2048) -> List[Dict]:
    rows = []
    for name, builder in IMAGE.items():
        base = unoptimized(builder(size))
        sh = scalehls_like(builder(size))
        pm = pom(builder(size))
        ps, pp = PAPER_IMAGE[name]
        rows.append({
            "bench": name, "size": size,
            "pom_speedup": base.report.latency / pm.report.latency,
            "scalehls_like_speedup": base.report.latency / sh.report.latency,
            "pom_ii": max(nd.ii for nd in pm.report.nodes.values()),
            "paper_pom": pp, "paper_scalehls": ps,
            "dse_seconds": pm.seconds,
        })
    return rows


def run_dnn(net: str = "resnet18", budget_frac: float = 1.0) -> Dict:
    """Aggregate latency over the net's critical conv loops.

    POM strategy: sequential layers, each DSE'd with the full resource
    budget (resource reuse between layers) -> total = sum(per-layer
    optimized latency).
    ScaleHLS-like dataflow: each layer gets budget/#layers as a pipeline
    stage; a single inference traverses every stage, so its latency is the
    sum of per-layer latencies at the 1/L budget (paper Fig. 13: per-layer
    parallelism degrades to ~1, hurting large-#layer nets).
    """
    table = conv_table(net)
    L = len(table)
    full = dict(XC7Z020)
    split = {k: (v / L if k != "bram_bits" else v / L) for k, v in XC7Z020.items()}

    # real nets repeat layer shapes ([(512, 512, 32)] * 3, ...); DSE results
    # depend only on the shape, so evaluate each distinct shape once
    seq_total = 0
    base_total = 0
    df_total = 0
    memo = {}
    for idx, (oc, ic, hw) in enumerate(table):
        key = (oc, ic, hw)
        if key not in memo:
            def builder():
                return conv_nest(f"{net}_conv{idx}", oc, ic, hw, hw)
            base = unoptimized(builder())
            from repro.core.dse import auto_dse
            res_full = auto_dse(builder().fn, resources=full, max_parallel=64)
            res_split = auto_dse(builder().fn, resources=split, max_parallel=64)
            memo[key] = (base.report.latency, res_full.report.latency,
                         res_split.report.latency)
        b, s, d = memo[key]
        base_total += b
        seq_total += s
        df_total += d

    pom_speedup = base_total / seq_total
    scalehls_speedup = base_total / df_total
    ps, pp = PAPER_DNN[net]
    return {
        "net": net, "layers": L,
        "pom_speedup": pom_speedup,
        "scalehls_like_speedup": scalehls_speedup,
        "paper_pom": pp, "paper_scalehls": ps,
    }


def csv_rows(image_size: int = 2048, dnn: bool = True) -> List[str]:
    out = []
    for r in run_image(image_size):
        out.append(f"image/{r['bench']},{r['dse_seconds'] * 1e6:.0f},"
                   f"pom_speedup={r['pom_speedup']:.1f}x;"
                   f"scalehls_like={r['scalehls_like_speedup']:.1f}x;"
                   f"paper_pom={r['paper_pom']}x")
    if dnn:
        for net in ("vgg16", "resnet18"):
            r = run_dnn(net)
            out.append(f"dnn/{net},0,pom_speedup={r['pom_speedup']:.1f}x;"
                       f"scalehls_like={r['scalehls_like_speedup']:.1f}x;"
                       f"paper_pom={r['paper_pom']}x;"
                       f"paper_scalehls={r['paper_scalehls']}x")
    return out
