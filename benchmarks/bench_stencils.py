"""Table VII: complicated access patterns (Jacobi-1d/2d, Heat-1d, Seidel).

The paper's claim: POM finds skewing-based schedules where loop-level
frameworks fail to improve at all (22.9x .. 136x vs baseline).
"""
from __future__ import annotations

from typing import Dict, List

from .baselines import pom, scalehls_like, unoptimized
from .workloads import STENCILS

PAPER = {"jacobi1d": 47.6, "jacobi2d": 136.0, "heat1d": 22.9, "seidel": 53.8}
SIZES = {"jacobi1d": 4096, "jacobi2d": 1024, "heat1d": 4096, "seidel": 500}


def run() -> List[Dict]:
    rows = []
    for name, builder in STENCILS.items():
        n = SIZES[name]
        base = unoptimized(builder(n))
        sh = scalehls_like(builder(n))
        pm = pom(builder(n))
        rows.append({
            "bench": name, "size": n,
            "pom_speedup": base.report.latency / pm.report.latency,
            "scalehls_like_speedup": base.report.latency / sh.report.latency,
            "pom_ii": max(nd.ii for nd in pm.report.nodes.values()),
            "pom_dsp": pm.report.dsp,
            "dse_seconds": pm.seconds,
            "paper_speedup": PAPER[name],
        })
    return rows


def csv_rows() -> List[str]:
    out = []
    for r in run():
        out.append(f"stencil/{r['bench']},{r['dse_seconds'] * 1e6:.0f},"
                   f"pom_speedup={r['pom_speedup']:.1f}x;"
                   f"scalehls_like={r['scalehls_like_speedup']:.1f}x;"
                   f"ii={r['pom_ii']};paper={r['paper_speedup']}x")
    return out
