"""Table III: Polybench at size 4096 — POM vs ScaleHLS-like vs unoptimized.

Latency is the calibrated XC7Z020 HLS model (the paper's numbers are Vitis
synthesis-report estimates, same epistemic level).  Reports speedup,
achieved II, tile/unroll factors, parallelism degree and DSE seconds.
"""
from __future__ import annotations

from typing import Dict, List

from .baselines import pom, scalehls_like, unoptimized
from .workloads import POLYBENCH

# the paper's Table III reference numbers (speedup over unoptimized)
PAPER_SPEEDUP = {"gemm": 575.9, "bicg": 224.0, "gesummv": 223.2,
                 "2mm": 510.1, "3mm": 335.4}
PAPER_SCALEHLS = {"gemm": 576.1, "bicg": 41.7, "gesummv": 199.1,
                  "2mm": 31.0, "3mm": 40.1}


def run(size: int = 4096) -> List[Dict]:
    rows = []
    for name, builder in POLYBENCH.items():
        base = unoptimized(builder(size))
        sh = scalehls_like(builder(size))
        pm = pom(builder(size))
        row = {
            "bench": name,
            "size": size,
            "baseline_cycles": base.report.latency,
            "scalehls_like_speedup": base.report.latency / sh.report.latency,
            "pom_speedup": base.report.latency / pm.report.latency,
            "pom_vs_scalehls": sh.report.latency / pm.report.latency,
            "pom_ii": max(n.ii for n in pm.report.nodes.values()),
            "scalehls_ii": max(n.ii for n in sh.report.nodes.values()),
            "pom_parallelism": pm.report.parallelism,
            "scalehls_parallelism": sh.report.parallelism,
            "pom_tiles": pm.tiles,
            "pom_dsp": pm.report.dsp,
            "pom_feasible": pm.report.feasible,
            "dse_seconds": pm.seconds,
            "paper_pom_speedup": PAPER_SPEEDUP[name],
            "paper_scalehls_speedup": PAPER_SCALEHLS[name],
        }
        rows.append(row)
    return rows


def csv_rows(size: int = 4096) -> List[str]:
    out = []
    for r in run(size):
        est_us = r["baseline_cycles"] / r["pom_speedup"] / 100.0  # 100 MHz
        out.append(
            f"polybench/{r['bench']},{est_us:.1f},"
            f"pom_speedup={r['pom_speedup']:.1f}x;"
            f"scalehls_like={r['scalehls_like_speedup']:.1f}x;"
            f"pom_ii={r['pom_ii']};par={r['pom_parallelism']:.1f};"
            f"paper_pom={r['paper_pom_speedup']}x;"
            f"dse_s={r['dse_seconds']:.1f}")
    return out
