"""Fig. 15: lines-of-code comparison -- POM DSL vs generated HLS C.

Counts: (a) DSL algorithm spec, (b) DSL + autoDSE one-liner, (c) DSL with
manually specified primitives (the schedule the DSE found, written by
hand), (d) the generated HLS C.
"""
from __future__ import annotations

import inspect
from typing import Dict, List

from repro.core.astbuild import build_ast
from repro.core.backend_hls import emit_hls
from repro.core.dse import auto_dse
from . import workloads


def _loc_of_builder(fn) -> int:
    src = inspect.getsource(fn)
    lines = [l for l in src.splitlines()
             if l.strip() and not l.strip().startswith(("#", '"""', "'''"))]
    return len(lines)


def run() -> List[Dict]:
    rows = []
    for name, builder in {**workloads.POLYBENCH, **workloads.STENCILS}.items():
        size = 64
        f = builder(size)
        dsl_loc = _loc_of_builder(builder)
        res = auto_dse(f.fn)
        n_actions = len(res.actions) + len(res.stage1_log.actions)
        hls = emit_hls(f.fn, build_ast(f.fn))
        hls_loc = len([l for l in hls.splitlines() if l.strip()])
        rows.append({
            "bench": name,
            "dsl_loc": dsl_loc,
            "dsl_autodse_loc": dsl_loc + 1,          # + f.auto_DSE()
            "dsl_manual_loc": dsl_loc + n_actions,   # schedule lines by hand
            "hls_c_loc": hls_loc,
            "ratio": hls_loc / (dsl_loc + 1),
        })
    return rows


def csv_rows() -> List[str]:
    out = []
    for r in run():
        out.append(f"loc/{r['bench']},0,dsl={r['dsl_loc']};"
                   f"dsl_autodse={r['dsl_autodse_loc']};"
                   f"manual={r['dsl_manual_loc']};hls_c={r['hls_c_loc']};"
                   f"ratio={r['ratio']:.1f}x")
    return out
