"""Table IV: manual expert optimization vs auto-DSE on BICG.

The 'manual' schedule encodes what an expert without polyhedral machinery
writes: interchange the whole nest to help the q-statement, pipeline+unroll
the inner loop, partition arrays -- the paper's manual design reached 161x
with 94% DSPs; the DSE beat it at 224x with 72% DSPs.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.cost_model import HlsModel
from repro.core.dse import _apply_parallel, refresh_partitions
from .baselines import _fn, pom, scalehls_like, unoptimized
from .workloads import bicg

PAPER = {"unopt_cycles": 234_889_217, "manual": 161.1, "dse": 224.0}


def run(n: int = 4096) -> Dict:
    base = unoptimized(bicg(n))
    # manual: whole-nest interchange + unroll 32 each statement
    fn = _fn(bicg(n))
    sh = scalehls_like(fn, max_parallel=64)  # the expert-equivalent schedule
    manual_lat = sh.report.latency
    pm = pom(bicg(n))
    return {
        "unopt_cycles": base.report.latency,
        "paper_unopt_cycles": PAPER["unopt_cycles"],
        "manual_speedup": base.report.latency / manual_lat,
        "dse_speedup": base.report.latency / pm.report.latency,
        "dse_dsp": pm.report.dsp,
        "paper_manual": PAPER["manual"],
        "paper_dse": PAPER["dse"],
    }


def csv_rows() -> List[str]:
    r = run()
    return [f"manual_vs_dse/bicg,{r['unopt_cycles'] / 100:.0f},"
            f"manual={r['manual_speedup']:.1f}x;dse={r['dse_speedup']:.1f}x;"
            f"paper_manual={r['paper_manual']}x;paper_dse={r['paper_dse']}x;"
            f"unopt_cycles={r['unopt_cycles']};"
            f"paper_unopt={r['paper_unopt_cycles']}"]
