"""Kernel-level benchmark: measured CPU wall time of the executable paths +
the POM-DSE schedule decisions for the TPU target.

Wall times on this CPU container cover the pure-jnp reference path (XLA
compiled) and the Pallas kernels in interpret mode at small shapes (their
numbers validate correctness-at-speed, not TPU performance -- TPU roofline
projections come from the autotuner's analytical terms).
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.kernels.autotune import (pom_attention_schedule, pom_matmul_schedule,
                                    pom_scan_schedule)


def _time(fn, *args, iters: int = 5) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> List[Dict]:
    rng = np.random.default_rng(0)
    rows = []

    # matmul: measured ref vs pallas-interpret at 256, + TPU schedule at 4096
    m = 256
    x = jnp.asarray(rng.normal(size=(m, m)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(m, m)), jnp.float32)
    t_ref = _time(jax.jit(ref.matmul), x, y)
    s = pom_matmul_schedule(4096, 4096, 4096, 2)
    rows.append({"name": "kernel/matmul_ref_256", "us": t_ref,
                 "derived": f"pom_tpu_schedule=({s.bm},{s.bn},{s.bk});"
                            f"bound={s.terms.dominant};"
                            f"roofline_s={s.terms.bound_s:.2e}"})

    # attention
    b, h, sq, d = 1, 4, 256, 64
    q = jnp.asarray(rng.normal(size=(b, h, sq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, h, sq, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, h, sq, d)), jnp.float32)
    t_ref = _time(jax.jit(lambda q, k, v: ref.attention(q, k, v)), q, k, v)
    sa = pom_attention_schedule(32768, 32768, 128, 2, True)
    rows.append({"name": "kernel/attention_ref_256", "us": t_ref,
                 "derived": f"pom_tpu_schedule=(bq={sa.bq},bkv={sa.bkv});"
                            f"bound={sa.terms.dominant}"})

    # ssm scan: sequential vs chunked on CPU (the POM-split win is real even
    # on CPU: chunked form vectorizes)
    b2, s2, h2, p2, n2 = 2, 2048, 4, 32, 16
    xs = jnp.asarray(rng.normal(size=(b2, s2, h2, p2)), jnp.float32)
    a2 = jnp.asarray(rng.uniform(0.7, 1.0, size=(b2, s2, h2)), jnp.float32)
    bb = jnp.asarray(rng.normal(size=(b2, s2, h2, n2)), jnp.float32)
    cc = jnp.asarray(rng.normal(size=(b2, s2, h2, n2)), jnp.float32)
    t_seq = _time(jax.jit(lambda *a: ref.ssm_scan(*a)[0]), xs, a2, bb, cc)
    t_chk = _time(jax.jit(lambda *a: ref.ssm_scan_chunked(*a)[0]),
                  xs, a2, bb, cc)
    sc = pom_scan_schedule(32768, 64, 64, 2)
    rows.append({"name": "kernel/ssm_scan_sequential_2k", "us": t_seq,
                 "derived": "formulation=recurrence"})
    rows.append({"name": "kernel/ssm_scan_chunked_2k", "us": t_chk,
                 "derived": f"speedup_vs_seq={t_seq / t_chk:.1f}x;"
                            f"pom_chunk={sc.chunk};"
                            f"bound={sc.terms.dominant}"})

    # stencil
    g = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    t_j = _time(jax.jit(lambda x: ref.jacobi2d(x, 1)), g)
    rows.append({"name": "kernel/jacobi2d_ref_256", "us": t_j,
                 "derived": "halo=blockspec-clamped"})
    return rows


def csv_rows() -> List[str]:
    return [f"{r['name']},{r['us']:.1f},{r['derived']}" for r in run()]
